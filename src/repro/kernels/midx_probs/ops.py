"""Jit'd public wrapper: MIDX proposal tables via the Pallas kernel.

`use_kernel=False` (or non-TPU backends) falls back to the jnp oracle —
the dry-run compiles the XLA path; TPU runs the fused kernel.

The kernel path is differentiable (custom_vjp): `log Q` from these tables
carries gradient back into the query z (and, with learnable codebooks, the
codebooks), so the fused training head needs d(tables)/dz. The backward
recomputes through the jnp oracle — three K-wide GEMMs, [T, K] transients
only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.index import MultiIndex
from repro.kernels.midx_probs.midx_probs import midx_probs
from repro.kernels.midx_probs.ref import midx_probs_ref


def _pad_t(x, block_t):
    t = x.shape[0]
    pad = (-t) % block_t
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], 0)
    return x, t


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _tables_op(z2d, cb1, cb2, counts, split: bool, block_t: int,
               interpret: bool):
    """Kernel-backed tables with an oracle-recompute VJP.
    z2d [T, D] -> (s1, s2, log_psi [T, K], lse [T, 1])."""
    zp, t0 = _pad_t(z2d, block_t)
    s1, s2, lpsi, lse = midx_probs(zp, cb1, cb2, counts, split=split,
                                   block_t=block_t, interpret=interpret)
    return s1[:t0], s2[:t0], lpsi[:t0], lse[:t0]


def _tables_fwd(z2d, cb1, cb2, counts, split, block_t, interpret):
    out = _tables_op(z2d, cb1, cb2, counts, split, block_t, interpret)
    return out, (z2d, cb1, cb2, counts)


def _tables_bwd(split, block_t, interpret, res, g):
    z2d, cb1, cb2, counts = res

    def oracle(z, c1, c2):
        s1, s2, lpsi, lse = midx_probs_ref(z, c1, c2, counts, split=split)
        return s1, s2, lpsi, lse[:, None]

    _, vjp = jax.vjp(oracle, z2d, cb1, cb2)
    dz, dc1, dc2 = vjp(g)
    return dz, dc1, dc2, jnp.zeros_like(counts)


_tables_op.defvjp(_tables_fwd, _tables_bwd)


def proposal_tables(index: MultiIndex, z: jax.Array, *, use_kernel: bool = True,
                    block_t: int = 256, interpret: bool = False):
    """z [..., D] -> (s1, s2, log_psi [..., K], lse [...]). Kernel-fused on
    TPU; identical semantics to repro.core.midx.twostage_tables. Both paths
    are differentiable w.r.t. z and the codebooks."""
    split = index.kind == "pq"
    lead = z.shape[:-1]
    z2d = z.reshape(-1, z.shape[-1])
    counts = index.counts.astype(jnp.float32)
    if not use_kernel:
        s1, s2, lpsi, lse = midx_probs_ref(z2d, index.codebook1,
                                           index.codebook2, counts,
                                           split=split)
        lse = lse[:, None]
    else:
        s1, s2, lpsi, lse = _tables_op(z2d, index.codebook1, index.codebook2,
                                       counts, split, block_t, interpret)
    k = s1.shape[-1]
    return (s1.reshape(*lead, k), s2.reshape(*lead, k),
            lpsi.reshape(*lead, k), lse.reshape(*lead))


# ---------------------------------------------------------------------------
# quantized codebooks (DESIGN §12): the kernel consumes the 1-byte codebook
# copies and dequantizes the scores after the dot. The VJP routes the z
# cotangent through the dequantized-oracle recompute; the low-bit codebooks
# and their scales are quantization artifacts, not trainable leaves, so
# their cotangents are None (learnable-codebook mode keeps the fp path).
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def _tables_q_op(z2d, qcb1, sc1, qcb2, sc2, counts, split: bool,
                 block_t: int, interpret: bool):
    zp, t0 = _pad_t(z2d, block_t)
    s1, s2, lpsi, lse = midx_probs(zp, qcb1, qcb2, counts, scale1=sc1,
                                   scale2=sc2, split=split, block_t=block_t,
                                   interpret=interpret)
    return s1[:t0], s2[:t0], lpsi[:t0], lse[:t0]


def _tables_q_fwd(z2d, qcb1, sc1, qcb2, sc2, counts, split, block_t,
                  interpret):
    out = _tables_q_op(z2d, qcb1, sc1, qcb2, sc2, counts, split, block_t,
                       interpret)
    return out, (z2d, qcb1, sc1, qcb2, sc2, counts)


def _tables_q_bwd(split, block_t, interpret, res, g):
    z2d, qcb1, sc1, qcb2, sc2, counts = res

    def oracle(z):
        s1, s2, lpsi, lse = midx_probs_ref(z, qcb1, qcb2, counts,
                                           scale1=sc1, scale2=sc2,
                                           split=split)
        return s1, s2, lpsi, lse[:, None]

    _, vjp = jax.vjp(oracle, z2d)
    (dz,) = vjp(g)
    return dz, None, None, None, None, None


_tables_q_op.defvjp(_tables_q_fwd, _tables_q_bwd)


def proposal_tables_q(index: MultiIndex, qcb1, sc1, qcb2, sc2, z: jax.Array,
                      *, use_kernel: bool = True, block_t: int = 256,
                      interpret: bool = False):
    """Quantized-codebook proposal tables: `index` supplies kind + counts,
    qcb1/qcb2 are the low-bit codebook copies with [K, 1] fp32 scales.
    Same outputs as proposal_tables; fused and jnp paths apply the scales
    in the same post-dot order, so they agree bit-for-bit."""
    split = index.kind == "pq"
    lead = z.shape[:-1]
    z2d = z.reshape(-1, z.shape[-1])
    counts = index.counts.astype(jnp.float32)
    if not use_kernel:
        s1, s2, lpsi, lse = midx_probs_ref(z2d, qcb1, qcb2, counts,
                                           scale1=sc1, scale2=sc2,
                                           split=split)
        lse = lse[:, None]
    else:
        s1, s2, lpsi, lse = _tables_q_op(z2d, qcb1, sc1, qcb2, sc2, counts,
                                         split, block_t, interpret)
    k = s1.shape[-1]
    return (s1.reshape(*lead, k), s2.reshape(*lead, k),
            lpsi.reshape(*lead, k), lse.reshape(*lead))
