"""Jit'd public wrapper: MIDX proposal tables via the Pallas kernel.

`use_kernel=False` (or non-TPU backends) falls back to the jnp oracle —
the dry-run compiles the XLA path; TPU runs the fused kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.index import MultiIndex
from repro.kernels.midx_probs.midx_probs import midx_probs
from repro.kernels.midx_probs.ref import midx_probs_ref


def _pad_t(x, block_t):
    t = x.shape[0]
    pad = (-t) % block_t
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], 0)
    return x, t


def proposal_tables(index: MultiIndex, z: jax.Array, *, use_kernel: bool = True,
                    block_t: int = 256, interpret: bool = False):
    """z [..., D] -> (s1, s2, log_psi [..., K], lse [...]). Kernel-fused on
    TPU; identical semantics to repro.core.midx.twostage_tables."""
    split = index.kind == "pq"
    lead = z.shape[:-1]
    z2d = z.reshape(-1, z.shape[-1])
    counts = index.counts.astype(jnp.float32)
    if not use_kernel:
        s1, s2, lpsi, lse = midx_probs_ref(z2d, index.codebook1,
                                           index.codebook2, counts,
                                           split=split)
        lse = lse[:, None]
    else:
        zp, t0 = _pad_t(z2d, block_t)
        s1, s2, lpsi, lse = midx_probs(zp, index.codebook1, index.codebook2,
                                       counts, split=split, block_t=block_t,
                                       interpret=interpret)
        s1, s2, lpsi, lse = (a[:t0] for a in (s1, s2, lpsi, lse))
    k = s1.shape[-1]
    return (s1.reshape(*lead, k), s2.reshape(*lead, k),
            lpsi.reshape(*lead, k), lse.reshape(*lead))
