"""Pallas TPU kernel: fused MIDX proposal tables (DESIGN §3).

One pass per query block, everything resident in VMEM:
  s1 = z1 @ C1ᵀ              (MXU)
  s2 = z2 @ C2ᵀ              (MXU)
  ψ  = exp(s2 − max) @ Wᵀ    (MXU; W = |Ω| counts, K×K)
  lse = logsumexp(s1 + logψ) (VPU)
vs. the unfused path: 3 reads of z from HBM + an HBM-materialized [T, K²]
joint table. Kernel writes 3K+1 floats per query.

Codebooks and the counts matrix are grid-invariant (index_map -> block 0),
so Mosaic keeps them in VMEM across the whole grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(z_ref, cb1_ref, cb2_ref, cnt_ref, *rest, split: bool,
            quantized: bool = False):
    if quantized:
        # low-bit codebooks: the [1, K] fp32 per-codeword scales dequantize
        # AFTER the dot — z @ (q·s)ᵀ = (z @ qᵀ)·sᵀ — so the MXU consumes the
        # 1-byte codebooks directly (DESIGN §12).
        sc1_ref, sc2_ref, s1_ref, s2_ref, lpsi_ref, lse_ref = rest
    else:
        s1_ref, s2_ref, lpsi_ref, lse_ref = rest
    z = z_ref[...].astype(jnp.float32)                 # [Tb, D]
    if split:
        d = z.shape[-1]
        z1, z2 = z[:, : d // 2], z[:, d // 2:]
    else:
        z1 = z2 = z
    cb1 = cb1_ref[...].astype(jnp.float32)             # [K, Dc]
    cb2 = cb2_ref[...].astype(jnp.float32)
    s1 = jax.lax.dot_general(z1, cb1, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    s2 = jax.lax.dot_general(z2, cb2, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    if quantized:
        s1 = s1 * sc1_ref[...]                         # [Tb, K] · [1, K]
        s2 = s2 * sc2_ref[...]
    c2 = jnp.max(s2, axis=-1, keepdims=True)
    e2 = jnp.exp(s2 - c2)                              # [Tb, K]
    cnt = cnt_ref[...].astype(jnp.float32)             # [K, K]
    psi = jax.lax.dot_general(e2, cnt, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    log_psi = jnp.log(jnp.maximum(psi, 1e-30)) + c2
    l1 = s1 + log_psi
    m = jnp.max(l1, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(l1 - m), axis=-1, keepdims=True)) + m
    s1_ref[...] = s1
    s2_ref[...] = s2
    lpsi_ref[...] = log_psi
    lse_ref[...] = lse


@functools.partial(jax.jit,
                   static_argnames=("split", "block_t", "interpret"))
def midx_probs(z: jax.Array, cb1: jax.Array, cb2: jax.Array,
               counts: jax.Array, *, scale1: jax.Array | None = None,
               scale2: jax.Array | None = None, split: bool,
               block_t: int = 256, interpret: bool = False):
    """z [T, D] -> (s1 [T,K], s2 [T,K], log_psi [T,K], lse [T,1]).
    scale1/scale2 != None: quantized mode — cb1/cb2 are the low-bit
    codebooks, the [K, 1] fp32 scales dequantize the scores after the dot."""
    t, d = z.shape
    k = cb1.shape[0]
    assert t % block_t == 0, (t, block_t)
    grid = (t // block_t,)
    quantized = scale1 is not None
    out_shape = (
        jax.ShapeDtypeStruct((t, k), jnp.float32),
        jax.ShapeDtypeStruct((t, k), jnp.float32),
        jax.ShapeDtypeStruct((t, k), jnp.float32),
        jax.ShapeDtypeStruct((t, 1), jnp.float32),
    )
    kernel = functools.partial(_kernel, split=split, quantized=quantized)
    in_specs = [
        pl.BlockSpec((block_t, d), lambda i: (i, 0)),
        pl.BlockSpec((k, cb1.shape[1]), lambda i: (0, 0)),
        pl.BlockSpec((k, cb2.shape[1]), lambda i: (0, 0)),
        pl.BlockSpec((k, k), lambda i: (0, 0)),
    ]
    operands = [z, cb1, cb2, counts]
    if quantized:
        in_specs += [pl.BlockSpec((1, k), lambda i: (0, 0)),
                     pl.BlockSpec((1, k), lambda i: (0, 0))]
        operands += [scale1.astype(jnp.float32).reshape(1, k),
                     scale2.astype(jnp.float32).reshape(1, k)]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
            pl.BlockSpec((block_t, 1), lambda i: (i, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
