"""Pure-jnp oracle for the fused MIDX proposal-table kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def midx_probs_ref(z: jax.Array, cb1: jax.Array, cb2: jax.Array,
                   counts: jax.Array, *, scale1: jax.Array | None = None,
                   scale2: jax.Array | None = None, split: bool):
    """z [T, D]; cb1/cb2 [K, Dc] (Dc = D/2 for PQ-split, D for RQ);
    counts [K, K] float32. Returns (s1, s2, log_psi, lse):
      s1/s2 [T, K] codeword scores,
      log_psi[t,k1] = log Σ_k2 counts[k1,k2]·exp(s2[t,k2]),
      lse[t]        = logsumexp_k1(s1 + log_psi)  (Eq.(6) normalizer).
    scale1/scale2 != None: quantized mode — [K, 1] fp32 per-codeword scales
    dequantize the scores AFTER the dot, matching the kernel's order of
    operations bit-for-bit.
    """
    zf = z.astype(jnp.float32)
    if split:
        d = z.shape[-1]
        z1, z2 = zf[:, : d // 2], zf[:, d // 2:]
    else:
        z1 = z2 = zf
    s1 = z1 @ cb1.T.astype(jnp.float32)
    s2 = z2 @ cb2.T.astype(jnp.float32)
    if scale1 is not None:
        s1 = s1 * scale1.astype(jnp.float32).reshape(1, -1)
        s2 = s2 * scale2.astype(jnp.float32).reshape(1, -1)
    c2 = jnp.max(s2, axis=-1, keepdims=True)
    psi = jnp.exp(s2 - c2) @ counts.T.astype(jnp.float32)
    log_psi = jnp.log(jnp.maximum(psi, 1e-30)) + c2
    l1 = s1 + log_psi
    lse = jax.nn.logsumexp(l1, axis=-1)
    return s1, s2, log_psi, lse
