from repro.checkpoint.manager import (CheckpointError, CheckpointManager,
                                      save_serving_state,
                                      restore_serving_state)
