from repro.checkpoint.manager import (CheckpointManager, save_serving_state,
                                      restore_serving_state)
