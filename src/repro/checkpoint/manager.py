"""Fault-tolerant checkpointing: atomic step dirs, keep-k GC, elastic restore.

Layout:
  <root>/step_<N>.tmp/...   (being written)
  <root>/step_<N>.old/...   (previous committed dir, mid-swap only)
  <root>/step_<N>/          (atomic rename on completion)
      arrays.npz            flattened leaves (global / fully-gathered values)
      tree.json             treedef + leaf dtypes/shapes/CRC32s + metadata

Fault-tolerance properties (DESIGN §4, hardened in §11):
  - atomic: a crash mid-save never corrupts the latest checkpoint — every
    file AND the directory entries are fsynced before the commit rename,
    and an existing committed dir is renamed aside (never rmtree'd) until
    the new one has landed; `_recover()` heals the aside dir on restart;
  - verifiable: tree.json records a CRC32 per leaf plus the treedef string;
    `verify`/`restore` recompute both, so silent byte corruption is caught
    instead of loaded into the optimizer;
  - restore fallback: `latest_verified_step` / `restore_latest_verified`
    walk back past corrupt or structurally mismatched steps to the newest
    checkpoint that verifies;
  - keep-k GC never deletes the most recent complete checkpoint;
  - `latest_step()` scans for *complete* dirs only;
  - elastic restore: arrays are saved with global shapes, so `restore` can
    re-shard onto any mesh (pass shardings=...); a job restarted at a
    different scale re-pjits the same values (DESIGN §4).
Data-pipeline position is stored in metadata → exact skip-ahead resume.

`fault_hook(phase, step)` is the resilience seam: when set (by
repro.resilience.FaultInjector.attach_checkpoint), save() calls it at the
phases 'arrays' | 'tree' | 'committed' | 'swap' so chaos tests can kill the
writer at any point of the commit protocol.
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


class CheckpointError(RuntimeError):
    """A checkpoint failed verification or structural matching."""


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def _fsync_path(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _leaf_crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _storable(arr: np.ndarray) -> np.ndarray:
    """np.savez only round-trips builtin dtypes; extension dtypes (bfloat16,
    float8_*) come back as opaque void fields. Store their raw bits as a
    same-width unsigned view — tree.json records the true dtype and restore
    views the bits back. The bytes are unchanged, so the recorded CRC32s
    cover the stored data either way."""
    if arr.dtype.kind == "V":
        return arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
    return arr


def _from_storable(arr: np.ndarray, dtype_str: Optional[str]) -> np.ndarray:
    """Undo `_storable` given the true dtype recorded in tree.json. Also
    heals checkpoints written before the raw-bits scheme, whose extension
    leaves load as void fields of the same width."""
    if dtype_str is None:
        return arr
    true = np.dtype(dtype_str)
    if arr.dtype != true and true.kind == "V" and \
            arr.dtype.itemsize == true.itemsize:
        return arr.view(true)
    return arr


def _parse_step(name: str) -> Optional[int]:
    if not name.startswith("step_") or name.endswith((".tmp", ".old")):
        return None
    try:
        return int(name.split("_")[1])
    except ValueError:
        return None


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self.fault_hook: Optional[Callable[[str, int], None]] = None
        os.makedirs(root, exist_ok=True)
        self._recover()

    # ------------------------------------------------------------- paths
    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:010d}")

    def _fault(self, phase: str, step: int) -> None:
        if self.fault_hook is not None:
            self.fault_hook(phase, step)

    def _recover(self) -> None:
        """Heal a crash mid-commit: a `.old` dir whose final dir is missing
        was renamed aside but never replaced — put it back. One whose final
        dir exists is debris from a crash after commit — drop it."""
        for name in os.listdir(self.root):
            if not name.endswith(".old"):
                continue
            aside = os.path.join(self.root, name)
            final = aside[: -len(".old")]
            if os.path.exists(final):
                shutil.rmtree(aside, ignore_errors=True)
            else:
                os.rename(aside, final)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return max(steps) if steps else None

    def all_steps(self) -> list[int]:
        out = []
        for name in sorted(os.listdir(self.root)):
            step = _parse_step(name)
            if step is not None and os.path.exists(
                    os.path.join(self.root, name, "COMMITTED")):
                out.append(step)
        return out

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, metadata: Optional[dict] = None) -> str:
        final = self._dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, treedef = _flatten_with_names(tree)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        arrays_path = os.path.join(tmp, "arrays.npz")
        np.savez(arrays_path,
                 **{f"leaf_{i}": _storable(l)
                    for i, l in enumerate(host_leaves)})
        self._fault("arrays", step)
        _fsync_path(arrays_path)
        spec = {
            "treedef": str(treedef),
            "num_leaves": len(host_leaves),
            "shapes": [list(l.shape) for l in host_leaves],
            "dtypes": [str(l.dtype) for l in host_leaves],
            "crc32": [_leaf_crc(l) for l in host_leaves],
            "metadata": metadata or {},
        }
        tree_path = os.path.join(tmp, "tree.json")
        with open(tree_path, "w") as f:
            json.dump(spec, f)
            f.flush()
            os.fsync(f.fileno())
        self._fault("tree", step)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
            f.flush()
            os.fsync(f.fileno())
        _fsync_path(tmp)               # directory entries of the tmp dir
        self._fault("committed", step)
        # commit: never a window without a complete checkpoint on disk —
        # the old dir is renamed aside (not rmtree'd) until the new one has
        # landed; _recover() heals either half of the swap after a crash
        old = final + ".old"
        if os.path.exists(final):
            if os.path.exists(old):
                shutil.rmtree(old)
            os.rename(final, old)
        self._fault("swap", step)
        os.rename(tmp, final)          # atomic commit
        _fsync_path(self.root)
        if os.path.exists(old):
            shutil.rmtree(old)
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._dir(s), ignore_errors=True)

    # ------------------------------------------------------------- verify
    def _spec(self, step: int) -> dict:
        with open(os.path.join(self._dir(step), "tree.json")) as f:
            return json.load(f)

    def verify(self, step: int, like: Any = None) -> list[str]:
        """Check a committed step without building arrays: tree.json parses,
        arrays.npz loads, per-leaf CRC32s match (when recorded), and — with
        `like` — leaf count and treedef string agree. Returns reasons;
        [] means the checkpoint is restorable."""
        d = self._dir(step)
        try:
            spec = self._spec(step)
        except Exception as e:                      # noqa: BLE001
            return [f"{d}: tree.json unreadable ({e!r})"]
        reasons = []
        try:
            with np.load(os.path.join(d, "arrays.npz")) as z:
                names = [f"leaf_{i}" for i in range(spec["num_leaves"])]
                if sorted(z.files) != sorted(names):
                    reasons.append(
                        f"{d}: arrays.npz holds {len(z.files)} leaves, "
                        f"tree.json promises {spec['num_leaves']}")
                else:
                    crcs = spec.get("crc32")
                    for i, name in enumerate(names):
                        leaf = z[name]
                        if crcs is not None and _leaf_crc(leaf) != crcs[i]:
                            reasons.append(
                                f"{d}: CRC32 mismatch on {name} "
                                "(silent corruption)")
        except Exception as e:                      # noqa: BLE001
            reasons.append(f"{d}: arrays.npz unreadable ({e!r})")
        if like is not None:
            like_leaves, treedef = _flatten_with_names(like)
            if spec["num_leaves"] != len(like_leaves):
                reasons.append(
                    f"{d}: checkpoint has {spec['num_leaves']} leaves, "
                    f"restore target has {len(like_leaves)}")
            if spec.get("treedef") and spec["treedef"] != str(treedef):
                reasons.append(f"{d}: treedef mismatch with restore target")
        return reasons

    def latest_verified_step(self, like: Any = None) -> Optional[int]:
        """Newest step that passes `verify` — the restore-fallback walk:
        corrupt or mismatched steps are skipped (and reported), older
        complete checkpoints remain eligible."""
        for step in reversed(self.all_steps()):
            reasons = self.verify(step, like)
            if not reasons:
                return step
            print(f"[ckpt] skipping step {step}: {'; '.join(reasons)}")
        return None

    # ------------------------------------------------------------- restore
    def metadata(self, step: int) -> dict:
        return self._spec(step)["metadata"]

    def restore(self, step: int, like: Any, *, shardings: Any = None,
                verify: bool = True) -> Any:
        """Restore into the structure of `like`. If `shardings` (a matching
        pytree of jax.sharding.Sharding) is given, device_put re-shards —
        this is the elastic-restore path (checkpoint saved on mesh A can be
        loaded onto mesh B). verify=True (default) additionally checks the
        recorded per-leaf CRC32s and the treedef string before any value is
        installed."""
        d = self._dir(step)
        spec = self._spec(step)
        like_leaves, treedef = _flatten_with_names(like)
        if spec["num_leaves"] != len(like_leaves):
            raise CheckpointError(
                f"{d}: checkpoint holds {spec['num_leaves']} leaves but the "
                f"restore target has {len(like_leaves)} — model/checkpoint "
                "mismatch")
        if verify and spec.get("treedef") and spec["treedef"] != str(treedef):
            raise CheckpointError(
                f"{d}: treedef mismatch — the checkpoint was saved from a "
                "different pytree structure than the restore target")
        dtypes = spec.get("dtypes") or [None] * spec["num_leaves"]
        with np.load(os.path.join(d, "arrays.npz")) as z:
            leaves = [_from_storable(z[f"leaf_{i}"], dtypes[i])
                      for i in range(len(z.files))]
        if verify and spec.get("crc32"):
            for i, leaf in enumerate(leaves):
                if _leaf_crc(leaf) != spec["crc32"][i]:
                    raise CheckpointError(
                        f"{d}: CRC32 mismatch on leaf_{i} — silent "
                        "corruption; use restore_latest_verified to walk "
                        "back to an intact checkpoint")
        cast = [np.asarray(l).astype(ll.dtype) if hasattr(ll, "dtype") else l
                for l, ll in zip(leaves, like_leaves)]
        if shardings is not None:
            sh_leaves, _ = _flatten_with_names(shardings)
            out = [jax.device_put(l, s) for l, s in zip(cast, sh_leaves)]
        else:
            out = [jnp.asarray(l) for l in cast]
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest_verified(self, like: Any, *,
                                shardings: Any = None) -> tuple[int, Any]:
        """Walk back to the newest checkpoint that verifies and restore it.
        Returns (step, tree); raises CheckpointError when nothing under the
        root survives verification."""
        step = self.latest_verified_step(like)
        if step is None:
            raise CheckpointError(
                f"no verifiable checkpoint under {self.root} "
                f"(candidates: {self.all_steps()})")
        return step, self.restore(step, like, shardings=shardings)


# ---------------------------------------------------------------------------
# serving checkpoints (DESIGN §5)
# ---------------------------------------------------------------------------
# The MIDX head's `MultiIndex` is a registered pytree, so its codebooks and
# CSR layout ride along as ordinary leaves — one atomic step dir holds
# everything the serving engine needs to restore sampling bit-exactly
# (save → restore → identical draws; see tests/test_serve.py).

def save_serving_state(root: str, step: int, params: Any, index: Any,
                       metadata: Optional[dict] = None) -> str:
    """Save a {"params", "index"} serving tree under `root`."""
    return CheckpointManager(root).save(
        step, {"params": params, "index": index}, metadata)


def restore_serving_state(root: str, like_params: Any, like_index: Any,
                          step: Optional[int] = None):
    """Restore (params, index, metadata). `like_*` only provide tree
    structure + leaf dtypes, so `jax.eval_shape` results work. With
    step=None the newest checkpoint that passes verification is used
    (corrupt ones are walked past)."""
    mgr = CheckpointManager(root)
    like = {"params": like_params, "index": like_index}
    if step is None:
        step, tree = mgr.restore_latest_verified(like)
    else:
        tree = mgr.restore(step, like)
    return tree["params"], tree["index"], mgr.metadata(step)
