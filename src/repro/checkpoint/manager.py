"""Fault-tolerant checkpointing: atomic step dirs, keep-k GC, elastic restore.

Layout:
  <root>/step_<N>.tmp/...   (being written)
  <root>/step_<N>/          (atomic rename on completion)
      arrays.npz            flattened leaves (global / fully-gathered values)
      tree.json             treedef + leaf dtypes/shapes + user metadata

Fault-tolerance properties:
  - atomic: a crash mid-save never corrupts the latest checkpoint (tmp dir
    is renamed only after fsync of all files);
  - keep-k GC never deletes the most recent complete checkpoint;
  - `latest_step()` scans for *complete* dirs only;
  - elastic restore: arrays are saved with global shapes, so `restore` can
    re-shard onto any mesh (pass shardings=...); a job restarted at a
    different scale re-pjits the same values (DESIGN §4).
Data-pipeline position is stored in metadata → exact skip-ahead resume.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------- paths
    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:010d}")

    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                full = os.path.join(self.root, name)
                if os.path.exists(os.path.join(full, "COMMITTED")):
                    steps.append(int(name.split("_")[1]))
        return max(steps) if steps else None

    def all_steps(self) -> list[int]:
        out = []
        for name in sorted(os.listdir(self.root)):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.root, name, "COMMITTED")):
                    out.append(int(name.split("_")[1]))
        return out

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, metadata: Optional[dict] = None) -> str:
        final = self._dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, treedef = _flatten_with_names(tree)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"leaf_{i}": l for i, l in enumerate(host_leaves)})
        spec = {
            "treedef": str(treedef),
            "num_leaves": len(host_leaves),
            "shapes": [list(l.shape) for l in host_leaves],
            "dtypes": [str(l.dtype) for l in host_leaves],
            "metadata": metadata or {},
        }
        with open(os.path.join(tmp, "tree.json"), "w") as f:
            json.dump(spec, f)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)          # atomic commit
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._dir(s), ignore_errors=True)

    # ------------------------------------------------------------- restore
    def metadata(self, step: int) -> dict:
        with open(os.path.join(self._dir(step), "tree.json")) as f:
            return json.load(f)["metadata"]

    def restore(self, step: int, like: Any, *, shardings: Any = None) -> Any:
        """Restore into the structure of `like`. If `shardings` (a matching
        pytree of jax.sharding.Sharding) is given, device_put re-shards —
        this is the elastic-restore path (checkpoint saved on mesh A can be
        loaded onto mesh B)."""
        d = self._dir(step)
        with np.load(os.path.join(d, "arrays.npz")) as z:
            leaves = [z[f"leaf_{i}"] for i in range(len(z.files))]
        like_leaves, treedef = _flatten_with_names(like)
        assert len(leaves) == len(like_leaves), "checkpoint/model mismatch"
        cast = [np.asarray(l).astype(ll.dtype) if hasattr(ll, "dtype") else l
                for l, ll in zip(leaves, like_leaves)]
        if shardings is not None:
            sh_leaves, _ = _flatten_with_names(shardings)
            out = [jax.device_put(l, s) for l, s in zip(cast, sh_leaves)]
        else:
            out = [jnp.asarray(l) for l in cast]
        return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# serving checkpoints (DESIGN §5)
# ---------------------------------------------------------------------------
# The MIDX head's `MultiIndex` is a registered pytree, so its codebooks and
# CSR layout ride along as ordinary leaves — one atomic step dir holds
# everything the serving engine needs to restore sampling bit-exactly
# (save → restore → identical draws; see tests/test_serve.py).

def save_serving_state(root: str, step: int, params: Any, index: Any,
                       metadata: Optional[dict] = None) -> str:
    """Save a {"params", "index"} serving tree under `root`."""
    return CheckpointManager(root).save(
        step, {"params": params, "index": index}, metadata)


def restore_serving_state(root: str, like_params: Any, like_index: Any,
                          step: Optional[int] = None):
    """Restore (params, index, metadata). `like_*` only provide tree
    structure + leaf dtypes, so `jax.eval_shape` results work."""
    mgr = CheckpointManager(root)
    if step is None:
        step = mgr.latest_step()
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {root}")
    tree = mgr.restore(step, {"params": like_params, "index": like_index})
    return tree["params"], tree["index"], mgr.metadata(step)
