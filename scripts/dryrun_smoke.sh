#!/usr/bin/env bash
# Fast coherence check for the distribution plan (DESIGN §4/§7): compile the
# paper's own LM through the production sharding on one small shape. Runs in
# well under a minute on CPU; the full matrix is `--all --mesh both`.
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.launch.dryrun --arch paper-lm --shape train_4k --mesh single "$@"
